"""Framework-vs-tailored on the LM workload (the paper's Fig. 3 experiment
shape applied to this framework's primary domain).

Tailored = one fused jitted train step (grad accumulation inside).
Framework = the same optimisation expressed as a HyPar job graph (GRAD
microbatch jobs with no_send_back + OPT job) on the LocalExecutor.
Numerical equivalence is asserted; the reported number is overhead %.

``run_dispatch_comparison`` additionally benchmarks the executor dispatch
modes (sync ``block_per_job`` vs pipelined vs dataflow, DESIGN.md §2.3) on
a multi-segment chunkwise graph over >=4 (virtual) devices — run this file
as __main__ so the device-count flag below takes effect before JAX starts.
"""
from __future__ import annotations

import os
import sys

if "jax" not in sys.modules and "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    # the dispatch comparison needs >=4 devices; harmless for the LM bench
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=4").strip()

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticLMStream
from repro.models.config import ModelConfig
from repro.optim import OptimizerSpec
from repro.train import HyParTrainer, TrainState, make_train_step

CFG = ModelConfig(name="bench-lm", family="dense", n_layers=4, d_model=256,
                  n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=4096,
                  compute_dtype="float32")


def run(steps: int = 10, n_micro: int = 2, batch: int = 8, seq: int = 128):
    spec = OptimizerSpec(kind="adamw", lr=1e-3)
    dc = DataConfig(global_batch=batch, seq_len=seq)
    stream = SyntheticLMStream(CFG, dc)
    batches_host = [stream.batch(s) for s in range(steps)]

    # tailored: fused jit
    step = jax.jit(make_train_step(CFG, spec, grad_accum=n_micro))
    state = TrainState.create(CFG, spec, jax.random.PRNGKey(0))
    b0 = jax.tree.map(jnp.asarray, batches_host[0])
    state, _ = step(state, b0)                       # compile
    state = TrainState.create(CFG, spec, jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    for b in batches_host:
        state, m = step(state, jax.tree.map(jnp.asarray, b))
    jax.block_until_ready(state.params)
    t_tailored = time.perf_counter() - t0

    # framework: HyPar scheduled
    mb = batch // n_micro
    hp_batches = [[{k: jnp.asarray(v[i * mb:(i + 1) * mb]) for k, v in b.items()}
                   for i in range(n_micro)] for b in batches_host]
    trainer = HyParTrainer(CFG, spec, n_micro=n_micro)
    t0 = time.perf_counter()
    fp, fo, report = trainer.run(hp_batches, key=jax.random.PRNGKey(0))
    t_hypar = time.perf_counter() - t0

    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(fp), jax.tree.leaves(state.params)))
    overhead = 100.0 * (t_hypar / t_tailored - 1.0)
    print(f"LM train {steps} steps: tailored {t_tailored:.2f}s | "
          f"hypar {t_hypar:.2f}s ({overhead:+.1f}%) | param diff {d:.1e} | "
          f"{report.summary()}")
    return {"tailored_s": t_tailored, "hypar_s": t_hypar,
            "overhead_pct": overhead, "param_diff": d}


def _dispatch_registry(dim: int):
    """One pre-jitted chunkwise matmul shared by every variant so the
    comparison times *dispatch*, never XLA compilation (the paper's users
    register compiled functions)."""
    from repro.core import FunctionRegistry

    W = jnp.eye(dim, dtype=jnp.float32) * 1.0001
    mm = jax.jit(lambda c: jnp.tanh(c @ W))
    mm(jnp.zeros((dim, dim), jnp.float32)).block_until_ready()  # compile now
    reg = FunctionRegistry()
    reg.register("mm", mm, kind="chunkwise")
    return reg


def _dispatch_graph(n_workers: int, n_segments: int, dim: int):
    """Multi-segment chunkwise chain: segment k holds one matmul job per
    worker consuming the same worker's segment-(k-1) result (no_send_back ⇒
    zero expected transfers under locality placement)."""
    from repro.core import ChunkRef, Job, JobGraph

    g = JobGraph()
    rng = np.random.default_rng(0)
    for k in range(n_segments):
        jobs = []
        for i in range(n_workers):
            deps = (ChunkRef(f"J{k - 1}_{i}"),) if k else ()
            jobs.append(Job(f"J{k}_{i}", "mm", 1, deps, no_send_back=True,
                            cost_hint=2.0 * dim * dim * dim))
        g.add_segment(jobs)
        if k == 0:
            for j in jobs:
                g.bind_input(j.name, jnp.asarray(
                    rng.standard_normal((dim, dim)).astype(np.float32)),
                    n_chunks=1)
    return g


def run_dispatch_comparison(n_segments: int = 12, dim: int = 512,
                            repeats: int = 5) -> dict:
    """Sync (block_per_job) vs pipelined vs dataflow wall time.

    The sequential baseline waits for every job's device work before
    dispatching the next, so each job pays host dispatch latency with idle
    devices; the async modes issue whole segments (pipelined) or the whole
    ready frontier (dataflow) and let XLA overlap transfers + compute.
    """
    from repro.core import LocalExecutor, VirtualCluster

    n_workers = min(4, len(jax.devices()))
    reg = _dispatch_registry(dim)
    variants = {
        "sync_block_per_job": dict(mode="sync", block_per_job=True),
        "pipelined": dict(mode="pipelined"),
        "dataflow": dict(mode="dataflow", strategy="cost"),
    }
    times: dict[str, float] = {}
    for name, kw in variants.items():
        best = float("inf")
        for r in range(repeats + 1):  # first run warms device allocations
            g = _dispatch_graph(n_workers, n_segments, dim)
            cluster = VirtualCluster(n_schedulers=1, max_workers=n_workers)
            ex = LocalExecutor(cluster, reg, **kw)
            t0 = time.perf_counter()
            results, report = ex.run(g)
            dt = time.perf_counter() - t0
            if r:  # discard warmup
                best = min(best, dt)
        times[name] = best
        print(f"  {name:>20}: {best * 1e3:8.1f} ms  ({report.summary()})")
    speedup = times["sync_block_per_job"] / times["pipelined"]
    print(f"  pipelined speedup over per-job blocking: {speedup:.2f}x "
          f"({n_workers} devices, {n_segments} segments, {dim}x{dim} matmuls)")
    return {"times_s": times, "pipelined_speedup": speedup,
            "n_devices": n_workers}


def run_proc_dispatch(width: int = 4, depth: int = 8, dim: int = 256,
                      repeats: int = 3) -> dict:
    """Thread (LocalExecutor) vs process (ProcessExecutor) dispatch on the
    IDENTICAL numpy workload (``repro.apps.procdemo``): width parallel
    matmul+tanh chains ending in one reduction.

    The thread row shares one GIL and one address space; the process row
    pays queue serialisation + a sqlite result write per job but runs truly
    parallel interpreters — the durable-runtime trade DESIGN.md §12
    documents.  Worker boot (spawn + import) happens once outside the timed
    region, like jit compilation everywhere else in this file.  Each repeat
    uses a fresh seed so the content-keyed store cannot turn the process
    repeats into memo hits.
    """
    from repro.apps import procdemo
    from repro.core import LocalExecutor, ProcessExecutor, VirtualCluster

    def shape(seed):
        return dict(width=width, depth=depth, dim=dim, seed=seed)

    expected = procdemo.expected_results(**shape(0))
    times: dict[str, float] = {}

    best = float("inf")
    for r in range(repeats + 1):   # r=0 warms allocations, then discarded
        ex = LocalExecutor(VirtualCluster(n_schedulers=1, max_workers=width),
                           procdemo.make_registry(host=True),
                           mode="pipelined")
        g = procdemo.build_graph(**shape(r))
        t0 = time.perf_counter()
        results, _ = ex.run(g)
        dt = time.perf_counter() - t0
        if r:
            best = min(best, dt)
        else:
            got = np.asarray(results["reduce"].arrays()[0])
            # thread workers round-trip bound inputs through the device
            # (float32 under default jax) — close, not bit-equal; the
            # process row below is held to bit-equality
            np.testing.assert_allclose(got, expected["reduce"][0],
                                       rtol=0, atol=1e-6)
    times["thread_pipelined"] = best

    ex = ProcessExecutor(VirtualCluster(n_schedulers=1, max_workers=width),
                         procdemo.make_registry(), procdemo.WORKER_FNS_SPEC,
                         mode="pipelined")
    with ex:
        ex._ensure_started()
        best = float("inf")
        for r in range(repeats + 1):
            g = procdemo.build_graph(**shape(r))
            t0 = time.perf_counter()
            results, _ = ex.run(g)
            dt = time.perf_counter() - t0
            if r:
                best = min(best, dt)
            else:
                got = np.asarray(results["reduce"].arrays()[0])
                np.testing.assert_array_equal(got, expected["reduce"][0])
        assert ex.n_memoised == 0, "repeats must not be memo hits"
    times["proc_pipelined"] = best

    n_jobs = width * (depth + 1) + 1
    ratio = 100.0 * (times["proc_pipelined"] / times["thread_pipelined"] - 1.0)
    print(f"  proc dispatch ({n_jobs} jobs, {width} workers, {dim}x{dim}): "
          f"thread {times['thread_pipelined'] * 1e3:.1f} ms | "
          f"proc {times['proc_pipelined'] * 1e3:.1f} ms ({ratio:+.1f}%)")
    return {"thread_s": times["thread_pipelined"],
            "proc_s": times["proc_pipelined"],
            "proc_vs_thread_pct": ratio, "n_jobs": n_jobs}


if __name__ == "__main__":
    print(f"== dispatch-mode comparison ({len(jax.devices())} devices)")
    run_dispatch_comparison()
    print("== process-worker dispatch (durable runtime)")
    run_proc_dispatch()
    print("== LM workload: framework vs tailored")
    run()
