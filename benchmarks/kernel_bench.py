"""Per-kernel microbenchmarks + the autotune pass.

Two row families, both in the stable BENCH schema
``{name, backend, shape, dtype, median_s, bytes, flops, ...}``:

* ``*_ref_*``   — jnp-oracle timings at benchmark shapes: the CPU perf
  trajectory (regressions in the references the dry-run lowers).
* ``*_tuned``   — the Pallas path timed through the autotuner
  (``repro.kernels.tuning``): on TPU the real kernels at benchmark shapes
  over the full candidate grids; elsewhere interpret mode at small shapes
  (the same machinery, exercised end-to-end — selection quality on CPU is
  a proxy, the *cache round-trip* is the contract).  Tuned entries land in
  the persistent cache, so a second run reuses them without re-timing and
  the ``ops.py`` wrappers + the scheduler cost model pick them up.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.jacobi_sweep.ops import jacobi_sweep, jacobi_sweep_residual
from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.runtime import on_tpu
from repro.kernels.ssd_scan.ops import ssd_intra_chunk


def _paged_inputs(key, B, H, KV, D, page_size, n_pages):
    """Serve-shaped decode inputs: full table rows (worst-case gather
    width), one pool page per logical page, three-quarter-full slots."""
    ks = jax.random.split(key, 5)
    P = 1 + B * n_pages
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, KV, page_size, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, KV, page_size, D), jnp.float32)
    tbl = jnp.arange(1, P, dtype=jnp.int32).reshape(B, n_pages)
    kv_len = jnp.full((B,), (3 * n_pages * page_size) // 4, jnp.int32)
    kt = jax.random.normal(ks[3], (B, KV, 1, D), jnp.float32)
    vt = jax.random.normal(ks[4], (B, KV, 1, D), jnp.float32)
    return q, kp, vp, tbl, kv_len, kt, vt


def _time(fn, *args, iters=5, **kw):
    """Median seconds per call (first call excluded: compile) — the same
    statistic Autotuner._time_call records, so `median_s` means the same
    thing in every BENCH row family."""
    jax.block_until_ready(fn(*args, **kw))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return samples[len(samples) // 2]


def bench_row(name, shape, dtype, median_s, *, flops=0.0, nbytes=0.0,
              **extra):
    """The one constructor of the stable BENCH row schema (ROADMAP): every
    suite's rows — kernels, jacobi, hypar — must come through here so a
    field change cannot skew one suite's cross-PR comparison silently."""
    r = {"name": name, "backend": jax.default_backend(), "shape": list(shape),
         "dtype": str(dtype), "median_s": median_s, "bytes": nbytes,
         "flops": flops}
    r.update(extra)
    return r


# ---------------------------------------------------------------------------
# Reference-path timings (perf trajectory of the jnp oracles)
# ---------------------------------------------------------------------------


def ref_rows(smoke: bool = False) -> list[dict]:
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    rows = []

    B, S, H, KV, D = (1, 256, 4, 2, 32) if smoke else (1, 1024, 8, 2, 64)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    s = _time(flash_attention, q, k, v, impl="ref")
    flops = 2 * 2 * B * H * S * S // 2 * D
    rows.append(bench_row("flash_attention_ref", (B, S, H, D), "float32", s,
                     flops=flops, nbytes=4.0 * (q.size + k.size + v.size)))

    BC, Hs, Q, P, N = (2, 2, 32, 16, 16) if smoke else (8, 8, 128, 64, 64)
    xh = jax.random.normal(ks[3], (BC, Hs, Q, P))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (BC, Hs, Q, 1)))
    a = -dt * 0.5
    Bm = jax.random.normal(ks[5], (BC, Q, N))
    Cm = jax.random.normal(ks[6], (BC, Q, N))
    s = _time(ssd_intra_chunk, xh, dt, a, Bm, Cm, impl="ref")
    rows.append(bench_row("ssd_intra_chunk_ref", (BC, Hs, Q, P, N), "float32", s,
                     flops=2.0 * BC * Hs * Q * Q * (P + N),
                     nbytes=4.0 * (xh.size + Bm.size + Cm.size)))

    R, d = (256, 512) if smoke else (4096, 1024)
    x = jax.random.normal(ks[0], (R, d), jnp.float32)
    g = jnp.ones((d,))
    s = _time(rmsnorm, x, g, impl="ref")
    rows.append(bench_row("rmsnorm_ref", (R, d), "float32", s,
                     flops=3.0 * x.size, nbytes=2.0 * x.size * 4))

    B, H, KV, D, ps, npg = (4, 4, 2, 32, 8, 4) if smoke else \
        (8, 8, 2, 64, 16, 16)
    q, kp, vp, tbl, kv_len, kt, vt = _paged_inputs(ks[7], B, H, KV, D,
                                                   ps, npg)
    s = _time(paged_decode_attention, q, kp, vp, tbl, kv_len, kt, vt,
              impl="ref")
    T = npg * ps
    rows.append(bench_row("paged_attention_ref", (B, H, T, D), "float32", s,
                     flops=2.0 * 2 * B * H * T * D,
                     nbytes=4.0 * 2 * B * T * KV * D))

    n = 512 if smoke else 2048
    A = jax.random.normal(ks[1], (n, n)) / n + jnp.eye(n) * 3
    xx = jax.random.normal(ks[2], (n,))
    b = jax.random.normal(ks[3], (n,))
    diag = jnp.diag(A)
    s = _time(jacobi_sweep, A, xx, b, diag, impl="ref")
    rows.append(bench_row("jacobi_sweep_ref", (n, n), "float32", s,
                     flops=2.0 * n * n, nbytes=4.0 * n * n))
    s = _time(jacobi_sweep_residual, A, xx, b, diag, impl="ref")
    rows.append(bench_row("jacobi_sweep_residual_ref", (n, n), "float32", s,
                     flops=2.0 * n * n, nbytes=4.0 * n * n))
    return rows


# ---------------------------------------------------------------------------
# Autotune pass (Pallas path; populates the persistent tuning cache)
# ---------------------------------------------------------------------------


def autotune_rows(smoke: bool = False) -> list[dict]:
    tuner = tuning.get_tuner()
    impl = "kernel" if on_tpu() else "interpret"
    tpu = on_tpu()
    ks = jax.random.split(jax.random.PRNGKey(1), 8)
    rows = []

    def tune(kernel, make_call, shape, cands, flops, nbytes):
        hit = tuner.observed_s(kernel, shape, jnp.float32) is not None
        entry = tuner.tune(kernel, make_call, shape=shape, dtype=jnp.float32,
                           candidates=cands, flops=flops, bytes_moved=nbytes)
        rows.append(bench_row(f"{kernel}_tuned", shape, "float32",
                         entry["median_s"], flops=flops, nbytes=nbytes,
                         config=entry["config"],
                         cache="hit" if hit else "miss"))

    # jacobi sweep (fused-residual path — the §4 hot loop)
    n = 2048 if tpu else (128 if smoke else 256)
    cands = (tuning.DEFAULT_CANDIDATES["jacobi_sweep"] if tpu else
             [{"row_block": r, "col_block": c}
              for r in (64, 128) for c in (64, 128)])
    A = jax.random.normal(ks[0], (n, n)) / n + jnp.eye(n) * 3
    x = jax.random.normal(ks[1], (n,))
    b = jax.random.normal(ks[2], (n,))
    d = jnp.diag(A)
    tune("jacobi_sweep",
         lambda cfg: (lambda: jacobi_sweep_residual(A, x, b, d, impl=impl,
                                                    **cfg)),
         (n, n), cands, 2.0 * n * n, 4.0 * n * n)

    # rmsnorm
    R, dd = (4096, 1024) if tpu else ((32, 128) if smoke else (64, 256))
    cands = (tuning.DEFAULT_CANDIDATES["rmsnorm"] if tpu else
             [{"row_block": r} for r in (8, 16, 32)])
    xr = jax.random.normal(ks[3], (R, dd), jnp.float32)
    g = jnp.ones((dd,))
    tune("rmsnorm",
         lambda cfg: (lambda: rmsnorm(xr, g, impl=impl, **cfg)),
         (R, dd), cands, 3.0 * xr.size, 2.0 * xr.size * 4)

    # flash attention
    B, S, H, KV, D = (1, 2048, 8, 2, 64) if tpu else (1, 128, 2, 2, 32)
    cands = (tuning.DEFAULT_CANDIDATES["flash_attention"] if tpu else
             [{"q_block": qb, "kv_block": kb}
              for qb in (64, 128) for kb in (64, 128)])
    q = jax.random.normal(ks[4], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[5], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[6], (B, S, KV, D), jnp.float32)
    fl = 2.0 * 2 * B * H * S * S // 2 * D
    tune("flash_attention",
         lambda cfg: (lambda: flash_attention(q, k, v, impl=impl, **cfg)),
         (B, S, H, D), cands, fl, 4.0 * (q.size + k.size + v.size))

    # ssd scan (no block params yet — timing feeds the cost-model bridge)
    BC, Hs, Q, P, N = (8, 8, 128, 64, 64) if tpu else (2, 2, 32, 16, 16)
    xh = jax.random.normal(ks[7], (BC, Hs, Q, P))
    dt = jax.nn.softplus(jax.random.normal(ks[0], (BC, Hs, Q, 1)))
    a = -dt * 0.5
    Bm = jax.random.normal(ks[1], (BC, Q, N))
    Cm = jax.random.normal(ks[2], (BC, Q, N))
    tune("ssd_scan",
         lambda cfg: (lambda: ssd_intra_chunk(xh, dt, a, Bm, Cm, impl=impl)),
         (BC, Hs, Q, P, N), [{}], 2.0 * BC * Hs * Q * Q * (P + N),
         4.0 * (xh.size + Bm.size + Cm.size))

    # paged flash-decode attention (in-kernel page gather, DESIGN.md §15)
    B2, H2, KV2, D2, ps2, np2 = (8, 8, 2, 64, 16, 16) if tpu else \
        (2, 4, 2, 32, 8, 4)
    pq, pk, pv, ptbl, plen, pkt, pvt = _paged_inputs(ks[3], B2, H2, KV2,
                                                     D2, ps2, np2)
    T2 = np2 * ps2
    tune("paged_attention",
         lambda cfg: (lambda: paged_decode_attention(
             pq, pk, pv, ptbl, plen, pkt, pvt, impl=impl, **cfg)),
         pq.shape, tuning.DEFAULT_CANDIDATES["paged_attention"],
         2.0 * 2 * B2 * H2 * T2 * D2, 4.0 * 2 * B2 * T2 * KV2 * D2)
    return rows


def run(smoke: bool = False, tune: bool = True) -> list[dict]:
    rows = ref_rows(smoke=smoke)
    if tune:
        rows += autotune_rows(smoke=smoke)
    return rows


if __name__ == "__main__":
    for r in run():
        extra = f" config={r['config']} cache={r['cache']}" if "config" in r else ""
        print(f"{r['name']},{r['median_s'] * 1e6:.1f}us{extra}")
