"""Per-kernel microbenchmarks (CPU reference path timings + interpret-mode
correctness cost).  On real TPU hardware the same harness times the Pallas
path; numbers here calibrate the CPU oracle and catch perf regressions in
the jnp reference implementations the dry-run lowers."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.jacobi_sweep.ops import jacobi_sweep
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.ssd_scan.ops import ssd_intra_chunk


def _time(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list[tuple[str, float, str]]:
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    rows = []

    B, S, H, KV, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    us = _time(flash_attention, q, k, v, impl="ref")
    flops = 2 * 2 * B * H * S * S // 2 * D
    rows.append(("flash_attention_ref_1k", us, f"{flops/us/1e3:.1f}GF/s"))

    BC, Hs, Q, P, N = 8, 8, 128, 64, 64
    xh = jax.random.normal(ks[3], (BC, Hs, Q, P))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (BC, Hs, Q, 1)))
    a = -dt * 0.5
    Bm = jax.random.normal(ks[5], (BC, Q, N))
    Cm = jax.random.normal(ks[6], (BC, Q, N))
    us = _time(ssd_intra_chunk, xh, dt, a, Bm, Cm, impl="ref")
    rows.append(("ssd_intra_chunk_ref", us, f"Q={Q},P={P},N={N}"))

    x = jax.random.normal(ks[0], (4096, 1024), jnp.float32)
    g = jnp.ones((1024,))
    us = _time(rmsnorm, x, g, impl="ref")
    rows.append(("rmsnorm_ref_4kx1k", us,
                 f"{x.size*4*2/us/1e3:.1f}GB/s"))

    n = 2048
    A = jax.random.normal(ks[1], (n, n)) / n + jnp.eye(n) * 3
    xx = jax.random.normal(ks[2], (n,))
    b = jax.random.normal(ks[3], (n,))
    us = _time(jacobi_sweep, A, xx, b, jnp.diag(A), impl="ref")
    rows.append(("jacobi_sweep_ref_2k", us, f"{2*n*n/us/1e3:.1f}GF/s"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
